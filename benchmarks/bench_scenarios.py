"""Scenario-batching benchmark: heterogeneous cases through one program.

Measures the cost of the RolloutEngine collect round for (a) a homogeneous
batch (every env the same Re=100 jets case — the paper's setup), (b) a
mixed batch of distinct cylinder scenarios (different Re / actuation /
probe layout) and (c) a mixed-GEOMETRY batch (cylinder + fluidic pinball):
per-env geometry gathered from the stacked bank, per-body vector actuation,
probe sets padded to a common width.  Scenario physics is traced data, so
(b) is the SAME XLA program as (a); (c) adds the bank gather and the
per-body force einsum and should still sit within the gate ratio.

Writes ``artifacts/BENCH_scenarios.json`` (``..._smoke.json`` with
``--smoke``; smoke artifacts never overwrite committed measurements).  The
gate — enforced by ``tools/bench_report.py --check`` — requires the
mixed-geometry collect to cost <= GATE_MAX_RATIO x the homogeneous collect.

    PYTHONPATH=src python benchmarks/bench_scenarios.py [--smoke]
"""
import argparse
import json
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT / "src"))
sys.path.insert(0, str(_ROOT))          # benchmarks.common as a script

import jax  # noqa: E402

from benchmarks.common import emit, time_fn  # noqa: E402
from repro.cfd.env import CylinderEnv, EnvConfig  # noqa: E402
from repro.cfd.grid import GridConfig  # noqa: E402
from repro.drl import networks  # noqa: E402
from repro.drl.engine import (EngineConfig, RolloutEngine,  # noqa: E402
                              broadcast_env_state)

BENCH_SCHEMA = "repro.bench_scenarios/v1"
MIX = ("cyl_re100", "cyl_re200", "cyl_re500", "cyl_re100_rotary")
GEO_MIX = ("cyl_re100", "pinball_re100", "cyl_re100_rotary", "pinball_re130")
# acceptance: serving cylinder+pinball from one vmapped program may cost at
# most this factor over the homogeneous cylinder batch
GATE_MAX_RATIO = 1.2


def run(smoke: bool = False, out: str = None) -> dict:
    iters = 1 if smoke else 3
    res, p_iters = (6, 20) if smoke else (10, 50)
    n_envs, horizon = (4, 2) if smoke else (8, 4)
    env = CylinderEnv(EnvConfig(
        grid=GridConfig(res=res, dt=0.008, poisson_iters=p_iters),
        steps_per_action=3 if smoke else 20,
        warmup_time=0.5 if smoke else 4.0))

    from repro.cfd.scenarios import get_scenario
    n_groups = len({(get_scenario(s).re, get_scenario(s).act_mode)
                    for s in MIX})
    t0 = time_fn(lambda s: env.reset_batch(MIX, n_envs)[0].scn.re,
                 None, iters=1, warmup=0)
    emit("scenario_warmup_vmapped", t0 * 1e6,
         f"groups={n_groups};n_envs={n_envs};res{res}")

    obs_dim = env.cfg.obs_dim
    params = networks.init_actor_critic(
        networks.PolicyConfig(obs_dim=obs_dim), jax.random.PRNGKey(0))
    engine = RolloutEngine.for_env(
        env, EngineConfig(n_envs=n_envs, horizon=horizon))

    # homogeneous batch (single scenario tiled, the paper's configuration)
    st, obs = env.reset()
    st_b, obs_b = broadcast_env_state(st, obs, n_envs)
    t_homo = time_fn(lambda p, k: engine.collect(p, st_b, obs_b, k),
                     params, jax.random.PRNGKey(1), iters=iters)
    emit("collect_homogeneous", t_homo * 1e6,
         f"n_envs={n_envs};horizon={horizon};res{res}")

    # mixed batch: 4 distinct scenarios, same batch shape, same program
    st_m, obs_m = env.reset_batch(MIX, n_envs, obs_dim=obs_dim)
    t_mix = time_fn(lambda p, k: engine.collect(p, st_m, obs_m, k),
                    params, jax.random.PRNGKey(2), iters=iters)
    emit("collect_mixed_scenarios", t_mix * 1e6,
         f"scenarios={len(MIX)};overhead_ratio={t_mix / t_homo:.3f}")

    # mixed-GEOMETRY batch: cylinder + pinball, per-body vector actuation,
    # per-env geometry gathered from the stacked bank (act_dim widens to 3)
    st_g, obs_g = env.reset_batch(GEO_MIX, n_envs, obs_dim=obs_dim)
    act_dim = int(st_g.jet_vel.shape[-1])
    params_g = networks.init_actor_critic(
        networks.PolicyConfig(obs_dim=obs_dim, act_dim=act_dim),
        jax.random.PRNGKey(0))
    t_geo = time_fn(lambda p, k: engine.collect(p, st_g, obs_g, k),
                    params_g, jax.random.PRNGKey(3), iters=iters)
    ratio = t_geo / t_homo
    emit("collect_mixed_geometry", t_geo * 1e6,
         f"scenarios={len(GEO_MIX)};act_dim={act_dim};"
         f"overhead_ratio={ratio:.3f}")

    record = {
        "schema": BENCH_SCHEMA,
        "config": {"smoke": smoke, "res": res, "poisson_iters": p_iters,
                   "n_envs": n_envs, "horizon": horizon,
                   "mix": list(MIX), "geo_mix": list(GEO_MIX)},
        "collect_homogeneous_s": t_homo,
        "collect_mixed_scenarios_s": t_mix,
        "collect_mixed_geometry_s": t_geo,
        "mixed_scenario_ratio": t_mix / t_homo,
        "mixed_geometry_ratio": ratio,
        "gate": {
            "metric": "mixed_geometry_ratio",
            "measured_ratio": ratio,
            "required_max": GATE_MAX_RATIO,
            # judged on the full-size measurement; smoke shapes are one
            # iteration at toy sizes where fixed overheads dominate the ratio
            "passed": bool(smoke or ratio <= GATE_MAX_RATIO),
        },
    }
    name = "BENCH_scenarios_smoke.json" if smoke else "BENCH_scenarios.json"
    path = Path(out) if out else _ROOT / "artifacts" / name
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(record, indent=1, sort_keys=True))
    verdict = "PASS" if record["gate"]["passed"] else "FAIL"
    if smoke:
        verdict = f"{verdict} (informational at smoke shapes)"
    print(f"artifact -> {path} (gate {verdict}: "
          f"{ratio:.3f} <= {GATE_MAX_RATIO})")
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, 1 iteration; writes "
                         "BENCH_scenarios_smoke.json")
    ap.add_argument("--out", default=None,
                    help="override the artifact path")
    args = ap.parse_args()
    run(smoke=args.smoke, out=args.out)


if __name__ == "__main__":
    main()
