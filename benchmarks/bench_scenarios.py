"""Scenario-batching benchmark: heterogeneous cases through one program.

Measures the cost of the RolloutEngine collect round for (a) a homogeneous
batch (every env the same Re=100 jets case — the paper's setup) and (b) a
mixed batch of distinct scenarios (different Re / actuation / probe layout)
of the same batch size.  Because scenario physics is traced data, (b) is the
SAME XLA program as (a): the emitted ratio should sit near 1.0 — the
scenario-diversity axis rides the "data"-axis parallelism for free.
"""
import jax

from benchmarks.common import emit, time_fn
from repro.cfd.env import CylinderEnv, EnvConfig
from repro.cfd.grid import GridConfig
from repro.drl import networks
from repro.drl.engine import EngineConfig, RolloutEngine, broadcast_env_state

MIX = ("cyl_re100", "cyl_re200", "cyl_re500", "cyl_re100_rotary")


def run(smoke: bool = False) -> None:
    iters = 1 if smoke else 3
    res, p_iters = (6, 20) if smoke else (10, 50)
    n_envs, horizon = (4, 2) if smoke else (8, 4)
    env = CylinderEnv(EnvConfig(
        grid=GridConfig(res=res, dt=0.008, poisson_iters=p_iters),
        steps_per_action=3 if smoke else 20,
        warmup_time=0.5 if smoke else 4.0))

    from repro.cfd.scenarios import get_scenario
    n_groups = len({(get_scenario(s).re, get_scenario(s).act_mode)
                    for s in MIX})
    t0 = time_fn(lambda s: env.reset_batch(MIX, n_envs)[0].scn.re,
                 None, iters=1, warmup=0)
    emit("scenario_warmup_vmapped", t0 * 1e6,
         f"groups={n_groups};n_envs={n_envs};res{res}")

    pcfg = networks.PolicyConfig()
    params = networks.init_actor_critic(pcfg, jax.random.PRNGKey(0))
    engine = RolloutEngine.for_env(
        env, EngineConfig(n_envs=n_envs, horizon=horizon))

    # homogeneous batch (single scenario tiled, the paper's configuration)
    st, obs = env.reset()
    st_b, obs_b = broadcast_env_state(st, obs, n_envs)
    t_homo = time_fn(lambda p, k: engine.collect(p, st_b, obs_b, k),
                     params, jax.random.PRNGKey(1), iters=iters)
    emit("collect_homogeneous", t_homo * 1e6,
         f"n_envs={n_envs};horizon={horizon};res{res}")

    # mixed batch: 4 distinct scenarios, same batch shape, same program
    st_m, obs_m = env.reset_batch(MIX, n_envs, obs_dim=env.cfg.obs_dim)
    t_mix = time_fn(lambda p, k: engine.collect(p, st_m, obs_m, k),
                    params, jax.random.PRNGKey(2), iters=iters)
    emit("collect_mixed_scenarios", t_mix * 1e6,
         f"scenarios={len(MIX)};overhead_ratio={t_mix / t_homo:.3f}")


if __name__ == "__main__":
    run()
